"""Chaos harness: property tests for the serving stack under seeded
fault schedules (serve/faults.py).

The three core properties, each asserted under multiple seeds:

  1. exactly-once termination — every submitted ticket ends in exactly
     one terminal status; the scheduler's in-band `_mark_terminal` gate
     raises `FatalError` on any double-termination, so completing a run
     *is* the proof;
  2. no KV leaks — after the run the block pool is byte-for-byte back to
     its fresh state: every block free, every allocator table empty,
     every slot returned (quarantined requests are scrubbed, not just
     released);
  3. fault isolation — requests the fault schedule never touched
     (no retries, no preemptions, no migrations) produce tokens bitwise
     identical to a fault-free run's.

Plus the degradation chain end-to-end (kernel faults under
`fallback="chain"` change *nothing* in any ticket's tokens, because the
backends are pinned bitwise-equal), replica failover on a meshless
`ReplicaSpread`, the clean path compiling zero guard programs, and the
deadline/cancel races the fault layer must not regress.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine as E
from repro.serve.faults import FatalError, FaultInjector
from repro.serve.scheduler import (ContinuousScheduler, ReplicaSpread,
                                   Scheduler)

jax.config.update("jax_platform_name", "cpu")

SEEDS = (1, 7, 23)
# Mixed prompt lengths and step counts so requests join/leave the decode
# batch at different steps (same shape as tests/test_continuous.py).
WORK = [((3, 1, 4, 1, 5), 6), ((9, 2, 6), 12), ((2, 7, 1, 8), 3),
        ((1, 1, 2, 3, 5, 8), 8)]
POOL = dict(max_len=32, num_blocks=24, block_size=8, max_batch=4)


def make_sched(cfg, params, **kw):
    return ContinuousScheduler(cfg, params, **POOL, **kw)


def pool_fresh_state(s):
    """The allocator/slot facts that must be restored after any run."""
    return (s.pool.allocator.free_blocks,
            sorted(len(tb) for tb in s.pool.allocator.tables.values()),
            len(s.pool._free_slots))


@pytest.fixture(scope="module")
def clean_tokens(smollm_reduced, smollm_params):
    """Fault-free reference tokens, keyed by rid (= submit order)."""
    s = make_sched(smollm_reduced, smollm_params)
    tickets = [s.submit(p, n) for p, n in WORK]
    s.run()
    assert all(t.status == "done" for t in tickets)
    return {t.rid: tuple(t.tokens) for t in tickets}


class TestChaosProperties:
    @pytest.mark.parametrize("seed", SEEDS)
    def test_termination_leaks_and_isolation(self, smollm_reduced,
                                             smollm_params, clean_tokens,
                                             seed):
        # max_fires bounds the blast radius: each fire touches at most
        # one request, so >= 1 of the 4 is always a clean-run control
        inj = FaultInjector(seed=seed, rates={
            "numerics": 0.08, "pool": 0.15, "latency": 0.05},
            latency_s=0.001, max_fires=3)
        s = make_sched(smollm_reduced, smollm_params, faults=inj)
        fresh = pool_fresh_state(s)
        tickets = [s.submit(p, n) for p, n in WORK]
        finished = s.run()   # raises FatalError on double-termination

        # 1. exactly-once termination: every ticket terminal, every
        #    terminal ticket surfaced by step() exactly once
        assert all(t.status in ("done", "failed") for t in tickets)
        assert sorted(id(t) for t in finished) \
            == sorted(id(t) for t in tickets)
        assert sorted(s._terminated) == sorted(t.rid for t in tickets)

        # 2. no KV leaks: pool allocator byte-for-byte fresh
        assert pool_fresh_state(s) == fresh
        assert s.pool.allocator.free_blocks \
            == s.pool.allocator.num_blocks - 1

        # 3. isolation: untouched requests match the clean run bitwise
        untouched = [t for t in tickets
                     if t.status == "done" and t.retries == 0
                     and t.preemptions == 0 and t.migrations == 0]
        assert untouched, "seed faulted every request; weaken the rates"
        for t in untouched:
            assert tuple(t.tokens) == clean_tokens[t.rid]
        # and every completed request has exactly its `steps` tokens
        for t in tickets:
            if t.status == "done":
                assert len(t.tokens) == t.steps

    @pytest.mark.parametrize("seed", SEEDS)
    def test_kernel_chaos_under_chain_is_invisible(self, smollm_reduced,
                                                   smollm_params,
                                                   clean_tokens, seed):
        """Kernel faults answered by the fallback chain never change a
        token: the chain re-runs the op on a bitwise-equal backend. The
        last-resort backend ("ref") is pinned fault-free so the chain is
        never exhausted — exhaustion is its own test in test_faults.py."""
        inj = FaultInjector(seed=seed, rates={"kernel": 0.25}, schedule={
            ("kernel", "dense:ref"): (), ("kernel", "gather:ref"): ()})
        s = make_sched(smollm_reduced, smollm_params, faults=inj,
                       guard=False)
        tickets = [s.submit(p, n) for p, n in WORK]
        s.run()
        assert all(t.status == "done" for t in tickets)
        for t in tickets:
            assert tuple(t.tokens) == clean_tokens[t.rid]
        # the hops were real and recorded
        if inj.fired["kernel"]:
            st = s.stats()
            assert st["fallbacks"] and all(
                src != dst for _, src, dst in st["fallbacks"])

    def test_pool_storm_retries_with_backoff(self, smollm_reduced,
                                             smollm_params, clean_tokens):
        """An admission-time pool storm requeues the request with
        deterministic backoff; it completes once the storm passes."""
        inj = FaultInjector(seed=3, schedule={("pool", "0"): (0, 1)})
        s = make_sched(smollm_reduced, smollm_params, faults=inj,
                       guard=False)
        t = s.submit(*WORK[0])
        s.run()
        assert t.status == "done" and t.retries == 2
        assert s.stats()["retries"] == 2
        # a retried admission re-prefills the same prompt: tokens match
        assert tuple(t.tokens) == clean_tokens[t.rid]

    def test_retry_budget_exhaustion_fails_cleanly(self, smollm_reduced,
                                                   smollm_params):
        inj = FaultInjector(seed=3, schedule={
            ("pool", "0"): tuple(range(10))})
        s = make_sched(smollm_reduced, smollm_params, faults=inj,
                       guard=False, max_retries=2)
        fresh = pool_fresh_state(s)
        t = s.submit(*WORK[0])
        s.run()
        assert t.status == "failed" and "retry budget exhausted" in t.error
        assert pool_fresh_state(s) == fresh

    def test_quarantine_preserves_batchmates(self, smollm_reduced,
                                             smollm_params, clean_tokens):
        """Poison one request's decode logits mid-batch: it fails, its
        blocks are scrubbed, and every batchmate's tokens stay bitwise
        identical to the clean run."""
        inj = FaultInjector(seed=0, schedule={("numerics", "1"): (2,)})
        s = make_sched(smollm_reduced, smollm_params, faults=inj)
        fresh = pool_fresh_state(s)
        tickets = [s.submit(p, n) for p, n in WORK]
        s.run()
        by_rid = {t.rid: t for t in tickets}
        assert by_rid[1].status == "failed"
        assert "non-finite" in by_rid[1].error
        for rid, t in by_rid.items():
            if rid != 1:
                assert t.status == "done"
                assert tuple(t.tokens) == clean_tokens[rid]
        assert pool_fresh_state(s) == fresh

    def test_double_termination_raises_fatal(self, smollm_reduced,
                                             smollm_params):
        s = make_sched(smollm_reduced, smollm_params)
        t = s.submit(*WORK[0])
        s.run()
        assert t.status == "done"
        with pytest.raises(FatalError, match="terminated twice|re-term"):
            s._mark_terminal(t, "failed")


class TestReplicaFailover:
    @pytest.mark.parametrize("seed", SEEDS)
    def test_replica_loss_migrates_and_completes(self, smollm_reduced,
                                                 smollm_params, seed):
        inj = FaultInjector(seed=seed, schedule={
            ("replica", "replica:0"): (2, 3)})
        sp = ReplicaSpread(smollm_reduced, smollm_params, replicas=2,
                           **POOL, faults=inj, trip_after=2,
                           probe_backoff_s=0.005)
        tickets = [sp.submit(p, n) for p, n in WORK]
        sp.run()
        st = sp.stats()
        assert all(t.status == "done" for t in tickets)
        assert all(len(t.tokens) == t.steps for t in tickets)
        assert st["health"][0]["trips"] == 1
        assert st["healthy_replicas"] == 2      # probe readmitted it
        # the lost replica's pool was abandoned; the survivors' pools
        # are clean
        for r in sp.replicas:
            assert not r._running and not r._waiting

    def test_all_replicas_down_orphans_then_recovers(self, smollm_reduced,
                                                     smollm_params):
        inj = FaultInjector(seed=5, schedule={
            ("replica", "replica:0"): (0,), ("replica", "replica:1"): (0,),
            ("replica", "probe:0"): (0,), ("replica", "probe:1"): (0,)})
        sp = ReplicaSpread(smollm_reduced, smollm_params, replicas=2,
                           **POOL, faults=inj, trip_after=1,
                           probe_backoff_s=0.002)
        tickets = [sp.submit(p, n) for p, n in WORK]
        sp.run()
        assert all(t.status == "done" for t in tickets)
        assert sp.stats()["orphans"] == 0
        assert sp.stats()["healthy_replicas"] == 2
        # both replicas tripped AND failed their first probe
        assert [h["trips"] for h in sp.stats()["health"]] == [1, 1]
        assert all(h["probes"] >= 2 for h in sp.stats()["health"])

    def test_unmigrated_tokens_bitwise_stable_across_failover(
            self, smollm_reduced, smollm_params):
        clean = ReplicaSpread(smollm_reduced, smollm_params, replicas=2,
                              **POOL)
        ct = [clean.submit(p, n) for p, n in WORK]
        clean.run()
        inj = FaultInjector(seed=11, schedule={
            ("replica", "replica:0"): (3, 4)})
        sp = ReplicaSpread(smollm_reduced, smollm_params, replicas=2,
                           **POOL, faults=inj, trip_after=2,
                           probe_backoff_s=0.005)
        ft = [sp.submit(p, n) for p, n in WORK]
        sp.run()
        assert sp.stats()["migrations"] >= 1
        for a, b in zip(ct, ft):
            assert b.status == "done" and len(b.tokens) == b.steps
            if b.migrations == 0:
                assert tuple(b.tokens) == tuple(a.tokens)

    def test_meshless_requires_exactly_one_mode(self, smollm_reduced,
                                                smollm_params):
        with pytest.raises(ValueError, match="exactly one"):
            ReplicaSpread(smollm_reduced, smollm_params, **POOL)


class TestCleanPathUnchanged:
    def test_no_guard_programs_without_injector(self, smollm_reduced,
                                                smollm_params):
        """faults=None compiles the unguarded programs — fault tolerance
        adds zero dispatches and zero program changes to the clean path."""
        s = make_sched(smollm_reduced, smollm_params)
        assert s.guard is False
        t = s.submit(*WORK[0])
        s.run()
        assert t.status == "done"
        for net in list(s._decode.values()) + list(s._prefill.values()):
            assert "-guard" not in net.program.name
        st = s.stats()
        assert st["fallbacks"] == [] and st["faults"] is None
        assert st["latency_spikes"] == 0 and st["decode_faults"] == 0

    def test_guard_opt_in_without_injector(self, smollm_reduced,
                                           smollm_params, clean_tokens):
        """guard=True with no injector: guard programs run but nothing
        fires — tokens stay bitwise identical to the unguarded run."""
        s = make_sched(smollm_reduced, smollm_params, guard=True)
        tickets = [s.submit(p, n) for p, n in WORK]
        s.run()
        for t in tickets:
            assert t.status == "done"
            assert tuple(t.tokens) == clean_tokens[t.rid]
        for net in s._decode.values():
            assert "-guard" in net.program.name


class TestDeadlineCancelRaces:
    """Satellite: the admission/expiry/cancel interleavings the fault
    layer must not regress."""

    @staticmethod
    def _toy_program():
        def fn(x):
            return jnp.tanh(x) * 2.0

        def avals(b):
            return (jax.ShapeDtypeStruct((b, 4), jnp.float32),)

        return E.trace_program(
            fn, *avals(1), name="toy", batch_size=1,
            batch_axes=E.infer_batch_axes(avals(1), avals(2)))

    def test_cancel_after_batch_dispatch_is_refused(self):
        """A ticket whose batch already ran cannot be cancelled — the
        result is retained and the cancel reports False."""
        s = Scheduler(max_batch=2)
        s.register("net", self._toy_program())
        t = s.submit("net", jnp.ones((1, 4), jnp.float32))
        served = s.step()
        assert t in served and t.done
        assert s.cancel(t) is False
        assert t.result is not None and not t.cancelled

    def test_deadline_expiring_between_admission_and_run(self):
        """A ticket admitted with a deadline that passes before any step
        expires instead of running — even though admission accepted it."""
        s = Scheduler()
        s.register("net", self._toy_program())
        t = s.submit("net", jnp.ones((1, 4), jnp.float32),
                     timeout_s=0.005)
        assert s.pending() == 1
        time.sleep(0.02)
        assert s.step() == []
        assert t.expired and not t.done and s.pending() == 0

    def test_continuous_deadline_expires_between_admit_and_decode(
            self, smollm_reduced, smollm_params):
        """A running request whose deadline lapses between decode steps
        is expired exactly once and its blocks return to the pool."""
        s = make_sched(smollm_reduced, smollm_params)
        fresh = pool_fresh_state(s)
        t = s.submit((1, 2, 3), 20, timeout_s=0.05)
        s.step()                      # admits (prefill) + first decode
        assert t.status == "running"
        time.sleep(0.08)
        s.step()
        assert t.status == "expired"
        assert pool_fresh_state(s) == fresh
        assert s._terminated == {t.rid: "expired"}

    def test_cancel_running_during_fault_storm(self, smollm_reduced,
                                               smollm_params):
        """Cancelling a running request mid-storm frees its blocks and
        the storm's retries never resurrect it."""
        inj = FaultInjector(seed=9, rates={"pool": 0.3})
        s = make_sched(smollm_reduced, smollm_params, faults=inj,
                       guard=False)
        fresh = pool_fresh_state(s)
        t = s.submit((1, 2, 3), 16)
        for _ in range(6):            # a few steps through the storm
            s.step()
            if t.status == "running":
                break
        if t.status == "running":
            assert s.cancel(t) is True
        else:                         # storm kept it queued: cancel there
            assert s.cancel(t) is True
        assert t.status == "cancelled"
        s.run()
        assert t.status == "cancelled"      # exactly-once: still cancelled
        assert pool_fresh_state(s) == fresh

    def test_spread_cancel_and_stats_with_unhealthy_replica(
            self, smollm_reduced, smollm_params):
        """Cancel must find a ticket routed to a replica that has since
        tripped (the ticket migrated with the drain), and stats() must
        stay well-formed while a replica is down."""
        inj = FaultInjector(seed=2, schedule={
            ("replica", "replica:0"): (0, 1),
            ("replica", "probe:0"): tuple(range(50))})
        sp = ReplicaSpread(smollm_reduced, smollm_params, replicas=2,
                           **POOL, faults=inj, trip_after=2,
                           probe_backoff_s=0.002)
        tickets = [sp.submit(p, n) for p, n in WORK]
        on_r0 = [t for t in tickets if t.replica == 0]
        assert on_r0
        sp.step()                     # fire 1: consecutive-failure count
        sp.step()                     # fire 2: trip + drain to replica 1
        st = sp.stats()
        assert st["healthy_replicas"] == 1
        assert st["health"][0]["healthy"] is False
        victim = on_r0[0]
        assert victim.replica == 1    # migrated by the drain
        assert sp.cancel(victim) is True
        assert victim.status == "cancelled"
        rest = [t for t in tickets if t is not victim]
        sp.run()
        assert all(t.status == "done" for t in rest)
        assert victim.status == "cancelled"
