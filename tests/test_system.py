"""End-to-end behaviour tests for the paper's system: the multi-mode engine
runs a CNN (conv mode) and an LM (FC mode) through ONE engine; training
makes progress; the crash/resume driver works."""
import math

import jax
import jax.numpy as jnp
import pytest

from repro.core import EngineConfig, MultiModeEngine

jax.config.update("jax_platform_name", "cpu")


def test_multi_mode_engine_runs_conv_and_fc():
    """The paper's headline: conv AND fc work on the same engine, and the
    engine's ledger prices both in the same PE currency."""
    eng = MultiModeEngine(EngineConfig(backend="xla", track_analytics=True))
    key = jax.random.PRNGKey(0)
    x_img = jax.random.normal(key, (1, 12, 12, 8))
    w_conv = jax.random.normal(key, (3, 3, 8, 16))
    y = eng.conv2d(x_img, w_conv, stride=1, pad=1)
    assert y.shape == (1, 12, 12, 16)
    x_vec = jax.random.normal(key, (4, 64))
    w_fc = jax.random.normal(key, (64, 32))
    z = eng.matmul(x_vec, w_fc)
    assert z.shape == (4, 32)
    x_seq = jax.random.normal(key, (2, 10, 6))
    w_1d = jax.random.normal(key, (4, 6))
    s = eng.conv1d_depthwise(x_seq, w_1d)
    assert s.shape == (2, 10, 6)
    kinds = {r.kind for r in eng.ledger}
    assert kinds == {"conv2d", "matmul", "conv1d_dw"}
    assert eng.total_cycles > 0 and 0 < eng.performance_efficiency <= 1.0


def test_end_to_end_train_and_generate():
    """Tiny LM: train a few steps, loss drops, then prefill+decode."""
    from repro.configs.base import reduced
    from repro.data import pipeline as dp
    from repro.launch.mesh import make_host_mesh
    from repro.models import transformer as T
    from repro.train import step as TS

    cfg = reduced("smollm_135m")
    mesh = make_host_mesh()
    ts, contract = TS.build_train_step(
        cfg, mesh, hyper=TS.TrainHyper(peak_lr=1e-3, warmup_steps=2,
                                       total_steps=12))
    params = T.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    opt = contract["opt_init"](params)
    dcfg = dp.DataConfig(seq_len=48, global_batch=4,
                         vocab_size=cfg.vocab_size)
    b0 = dp.lm_batch(cfg, dcfg, 0)
    shapes = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, jnp.asarray(x).dtype), b0)
    jitted = TS.jit_train_step(cfg, mesh, ts, contract, shapes)
    losses = []
    for step in range(12):
        batch = dp.lm_batch(cfg, dcfg, step)
        params, opt, m = jitted(params, opt, batch, jnp.int32(step))
        losses.append(float(m["loss"]))
    assert all(math.isfinite(l) for l in losses)
    assert losses[-1] < losses[0]

    prompt = {"tokens": dp.lm_batch(cfg, dcfg, 99)["tokens"][:2, :12]}
    logits, state = T.prefill(cfg, params, prompt, max_len=24)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    for i in range(4):
        lg, state = T.decode_step(cfg, params, state, tok, jnp.int32(12 + i))
        tok = jnp.argmax(lg[:, -1], -1).astype(jnp.int32)[:, None]
    assert tok.shape == (2, 1)
    assert not bool(jnp.isnan(lg).any())


def test_crash_resume_driver(tmp_path):
    """The launch/train.py fault-tolerance path: train, 'crash', resume."""
    from repro.launch import train as train_mod
    base = ["--arch", "smollm-135m", "--reduced",
            "--seq", "32", "--batch", "4", "--ckpt-dir", str(tmp_path),
            "--ckpt-every", "4", "--log-every", "5"]
    h1 = train_mod.main(base + ["--steps", "6"])      # 'crash' after 6
    h2 = train_mod.main(base + ["--steps", "10", "--resume"])
    assert h1 and h2, "resume produced no steps"
    assert h2[0]["step"] >= 4, h2                     # resumed, not restarted
