"""Substrate subsystems: loss, optimizers, data pipeline, checkpointing,
compression, sharding rules."""
import math
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import reduced
from repro.data import pipeline as dp
from repro.models import layers as L
from repro.optim import adafactor, adamw
from repro.parallel import compression as C
from repro.parallel import sharding as S
from repro.train.loss import chunked_softmax_xent

jax.config.update("jax_platform_name", "cpu")


class TestChunkedLoss:
    @given(v=st.integers(7, 200), vc=st.integers(3, 64))
    @settings(max_examples=20, deadline=None)
    def test_matches_direct_xent(self, v, vc):
        key = jax.random.PRNGKey(v)
        hidden = jax.random.normal(key, (2, 5, 16), jnp.float32)
        table = jax.random.normal(jax.random.PRNGKey(1), (v, 16), jnp.float32)
        labels = jax.random.randint(jax.random.PRNGKey(2), (2, 5), 0, v)
        got = chunked_softmax_xent(hidden, table, labels, v_chunk=vc)
        logits = hidden @ table.T
        want = -jnp.take_along_axis(
            jax.nn.log_softmax(logits, -1), labels[..., None], -1).mean()
        assert float(jnp.abs(got - want)) < 1e-4

    def test_mask_and_softcap(self):
        key = jax.random.PRNGKey(0)
        hidden = jax.random.normal(key, (2, 6, 8))
        table = jax.random.normal(jax.random.PRNGKey(1), (33, 8))
        labels = jax.random.randint(jax.random.PRNGKey(2), (2, 6), 0, 33)
        mask = jnp.array([[1, 1, 0, 0, 1, 1], [0, 1, 1, 1, 0, 0]], bool)
        got = chunked_softmax_xent(hidden, table, labels, mask,
                                   logit_softcap=30.0, v_chunk=8)
        logits = 30.0 * jnp.tanh((hidden @ table.T) / 30.0)
        nll = -jnp.take_along_axis(jax.nn.log_softmax(logits, -1),
                                   labels[..., None], -1)[..., 0]
        want = (nll * mask).sum() / mask.sum()
        assert float(jnp.abs(got - want)) < 1e-4


class TestOptimizers:
    def _quad_params(self):
        return {"w": jnp.array([1.0, -2.0, 3.0]),
                "b": jnp.ones((2, 4))}

    def test_adamw_descends(self):
        cfg = adamw.AdamWConfig(weight_decay=0.0, clip_norm=1e9)
        params = self._quad_params()
        state = adamw.init(params, cfg)
        loss = lambda p: (p["w"] ** 2).sum() + (p["b"] ** 2).sum()
        for i in range(80):
            g = jax.grad(loss)(params)
            params, state, _ = adamw.update(g, state, params,
                                            jnp.float32(0.05), cfg)
        assert float(loss(params)) < 0.5

    def test_adamw_matches_reference_step(self):
        cfg = adamw.AdamWConfig(b1=0.9, b2=0.999, eps=1e-8,
                                weight_decay=0.0, clip_norm=1e9)
        p = {"w": jnp.array([2.0])}
        st_ = adamw.init(p, cfg)
        g = {"w": jnp.array([0.5])}
        p2, st2, _ = adamw.update(g, st_, p, jnp.float32(0.1), cfg)
        m = 0.1 * 0.5 / (1 - 0.9)
        v = 0.001 * 0.25 / (1 - 0.999)
        want = 2.0 - 0.1 * m / (math.sqrt(v) + 1e-8)
        assert float(p2["w"][0]) == pytest.approx(want, rel=1e-5)

    def test_adafactor_descends_and_is_factored(self):
        cfg = adafactor.AdafactorConfig(clip_norm=1e9)
        params = {"w": jax.random.normal(jax.random.PRNGKey(0), (8, 6))}
        state = adafactor.init(params, cfg)
        assert state["v"]["w"]["vr"].shape == (8,)
        assert state["v"]["w"]["vc"].shape == (6,)
        loss = lambda p: (p["w"] ** 2).sum()
        start = float(loss(params))
        for _ in range(80):
            g = jax.grad(loss)(params)
            params, state, _ = adafactor.update(g, state, params,
                                                jnp.float32(0.05), cfg)
        assert float(loss(params)) < 0.2 * start

    def test_clip_by_global_norm(self):
        g = {"a": jnp.full((10,), 10.0)}
        clipped, norm = adamw.clip_by_global_norm(g, 1.0)
        assert float(norm) == pytest.approx(math.sqrt(1000.0), rel=1e-5)
        assert float(adamw.global_norm(clipped)) == pytest.approx(1.0, rel=1e-4)


class TestDataPipeline:
    def test_deterministic(self):
        cfg = dp.DataConfig(seq_len=32, global_batch=4, seed=7,
                            vocab_size=100)
        mcfg = reduced("smollm_135m")
        b1 = dp.lm_batch(mcfg, cfg, step=3)
        b2 = dp.lm_batch(mcfg, cfg, step=3)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])

    def test_steps_differ_and_shards_differ(self):
        cfg = dp.DataConfig(seq_len=32, global_batch=4, n_shards=2,
                            vocab_size=100)
        mcfg = reduced("smollm_135m")
        a = dp.lm_batch(mcfg, cfg, step=0, shard=0)
        b = dp.lm_batch(mcfg, cfg, step=1, shard=0)
        c = dp.lm_batch(mcfg, cfg, step=0, shard=1)
        assert (a["tokens"] != b["tokens"]).any()
        assert (a["tokens"] != c["tokens"]).any()

    def test_labels_are_shifted_tokens(self):
        cfg = dp.DataConfig(seq_len=16, global_batch=2, vocab_size=50)
        mcfg = reduced("smollm_135m")
        b = dp.lm_batch(mcfg, cfg, step=0)
        np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])

    def test_learnable_structure(self):
        """Planted bigram structure: follow-token rate ~50%."""
        cfg = dp.DataConfig(seq_len=512, global_batch=4, vocab_size=1000)
        t = dp.synthetic_tokens(cfg, 0, 0).astype(np.int64)
        follow = (t[:, :-1] * 2654435761 + 12345) % 1000
        rate = (t[:, 1:] == follow).mean()
        assert 0.15 < rate < 0.7

    def test_modality_batches(self):
        mcfg = reduced("hubert_xlarge")
        cfg = dp.DataConfig(seq_len=16, global_batch=2,
                            vocab_size=mcfg.vocab_size)
        b = dp.lm_batch(mcfg, cfg, 0)
        assert b["frames"].shape == (2, 16, mcfg.d_frontend)
        assert b["loss_mask"].dtype == bool


class TestCheckpoint:
    def _tree(self, key=0):
        k = jax.random.PRNGKey(key)
        return {"layer": {"w": jax.random.normal(k, (4, 6)),
                          "b": jnp.arange(3.0)},
                "count": jnp.int32(7)}

    def test_roundtrip(self, tmp_path):
        mgr = CheckpointManager(tmp_path)
        tree = self._tree()
        mgr.save(5, tree, extra={"step": 5})
        assert mgr.latest_step() == 5
        got = mgr.restore(5, jax.tree_util.tree_map(jnp.zeros_like, tree))
        for a, b in zip(jax.tree_util.tree_leaves(got),
                        jax.tree_util.tree_leaves(tree)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert mgr.restore_extra(5)["step"] == 5

    def test_async_and_gc(self, tmp_path):
        mgr = CheckpointManager(tmp_path, keep=2, async_write=True)
        for s in (1, 2, 3):
            mgr.save(s, self._tree(s))
        mgr.wait()
        assert mgr.latest_step() == 3
        assert 1 not in mgr._complete_steps()

    def test_incomplete_checkpoint_skipped(self, tmp_path):
        mgr = CheckpointManager(tmp_path)
        mgr.save(1, self._tree())
        # simulate a crashed writer
        (tmp_path / "step_00000009").mkdir()
        assert mgr.latest_step() == 1

    def test_shape_mismatch_rejected(self, tmp_path):
        mgr = CheckpointManager(tmp_path)
        mgr.save(1, {"w": jnp.zeros((3,))})
        with pytest.raises(ValueError):
            mgr.restore(1, {"w": jnp.zeros((4,))})


class TestCompression:
    def test_int8_roundtrip_error_bound(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (256,)) * 3
        q, s = C.quantize_int8(x)
        err = jnp.abs(C.dequantize_int8(q, s) - x).max()
        assert float(err) <= float(s) * 0.5 + 1e-6

    def test_error_feedback_reduces_bias(self):
        g = {"w": jnp.full((64,), 0.003)}
        e = C.init_error_state(g)
        total_plain = jnp.zeros((64,))
        total_ef = jnp.zeros((64,))
        for _ in range(20):
            total_plain += C.compress_tree_int8(g)["w"]
            gq, e = C.compress_tree_int8(g, e)
            total_ef += gq["w"]
        want = 20 * 0.003
        assert float(jnp.abs(total_ef - want).max()) \
            < float(jnp.abs(total_plain - want).max()) + 1e-6


class TestShardingRules:
    def _mesh(self):
        return jax.make_mesh((1, 1), ("data", "model"))

    def test_conflict_first_dim_wins(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        rules = S.make_rules(mesh)
        # (experts->model, d_model->data, d_ff->model-conflict)
        spec = S.spec_for((16, 32, 64),
                          (L.EXPERTS, L.D_MODEL, L.D_FF), rules, mesh)
        assert tuple(spec) in ((("model",), ("data",), None),
                               ("model", "data"))

    def test_nondivisible_falls_back(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        rules = S.ShardingRules(
            rules={L.HEADS: "model"}, dp_axes=("data",), tp_axis="model",
            fsdp_axes=("data",))
        # pretend model axis had size 16 via a fake mesh is hard on 1 dev;
        # the divisibility check uses mesh sizes — with size-1 axes any dim
        # divides, so verify the conflict path instead on real meshes in
        # tests/test_distributed.py.
        spec = S.spec_for((9,), (L.HEADS,), rules, mesh)
        assert len(tuple(spec)) <= 1
