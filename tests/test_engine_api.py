"""The plan-based engine API (repro.engine): backend parity across the
registry, plan purity/hashability (jit-cache stability), ledger semantics
under tracing, and the legacy MultiModeEngine shim equivalence."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine as E
from repro.core import EngineConfig, MultiModeEngine
from repro.models import cnn

# CPU platform pin + shared fixtures live in conftest.py

TABLE3_MODES = [(11, 4), (7, 2), (5, 1), (3, 1), (1, 1)]
BACKENDS = ("ref", "xla", "pallas")


# ---------------------------------------------------------------------------
# Backend parity
# ---------------------------------------------------------------------------

class TestBackendParity:
    @pytest.mark.parametrize("w_f,s", TABLE3_MODES)
    def test_conv2d_all_backends(self, w_f, s):
        x = jax.random.normal(jax.random.PRNGKey(0), (1, 23, 23, 8),
                              jnp.float32)
        w = jax.random.normal(jax.random.PRNGKey(1), (w_f, w_f, 8, 16),
                              jnp.float32)
        outs = {b: E.conv2d(x, w, stride=s, pad=w_f // 2, backend=b)
                for b in BACKENDS}
        for b in ("xla", "pallas"):
            np.testing.assert_allclose(outs[b], outs["ref"], rtol=2e-4,
                                       atol=2e-4)

    def test_dense_all_backends(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (3, 5, 48), jnp.float32)
        w = jax.random.normal(jax.random.PRNGKey(1), (48, 32), jnp.float32)
        outs = {b: E.dense(x, w, backend=b) for b in BACKENDS}
        for b in ("xla", "pallas"):
            np.testing.assert_allclose(outs[b], outs["ref"], rtol=1e-4,
                                       atol=1e-4)

    def test_conv1d_depthwise_all_backends(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (2, 17, 6), jnp.float32)
        w = jax.random.normal(jax.random.PRNGKey(1), (4, 6), jnp.float32)
        outs = {b: E.conv1d_depthwise(x, w, causal=True) for b in BACKENDS}
        for b in ("xla", "pallas"):
            np.testing.assert_allclose(outs[b], outs["ref"], rtol=1e-4,
                                       atol=1e-4)

    @pytest.mark.parametrize("spec,xs,ws", [
        ("...d,df->...f", (2, 5, 16), (16, 24)),     # FFN in-proj
        ("...d,vd->...v", (2, 5, 16), (40, 16)),     # tied unembed
        ("ecd,edf->ecf", (3, 7, 16), (3, 16, 8)),    # MoE expert GEMMs
        ("bhd,chd->bhc", (2, 4, 8), (10, 4, 8)),     # MLA absorbed W_uk
    ])
    def test_einsum_matches_jnp(self, spec, xs, ws):
        x = jax.random.normal(jax.random.PRNGKey(0), xs, jnp.float32)
        w = jax.random.normal(jax.random.PRNGKey(1), ws, jnp.float32)
        want = jnp.einsum(spec, x, w)
        for b in BACKENDS:
            got = E.einsum(spec, x, w, backend=b)
            np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Plans: pure, hashable, jit-cache stable
# ---------------------------------------------------------------------------

class TestPlans:
    def test_plan_pure_and_hashable(self):
        a = E.plan_conv2d((1, 12, 12, 8), (3, 3, 8, 16), 1, 1, 1, "xla")
        b = E.plan_conv2d((1, 12, 12, 8), (3, 3, 8, 16), 1, 1, 1, "xla")
        assert a == b and hash(a) == hash(b)
        assert {a: "v"}[b] == "v"                  # usable as dict key
        assert a.mode == E.plan_conv2d(
            (1, 12, 12, 8), (3, 3, 8, 16), 1, 1, 1, "pallas").mode

    def test_plan_matches_paper_mode(self):
        for w_f, s in TABLE3_MODES:
            p = E.plan_conv2d((1, 23, 23, 8), (w_f, w_f, 8, 16), s, 0, 1,
                              "xla")
            assert (p.mode.w_f, p.mode.s) == (w_f, s)
            assert p.macs > 0 and p.cycles > 0
            assert 0.0 < p.performance_efficiency <= 1.0

    def test_plan_tolerates_wide_1d_filters(self):
        # hubert's 128-tap positional conv exceeds the 11-register MMIE
        # weight generator; the plan books a derived schedule, no crash.
        p = E.plan_conv1d_depthwise((2, 64, 32), (128, 32), "xla")
        assert p.mode.w_f == 128 and p.cycles > 0

    def test_jit_cache_stable(self):
        traces = []

        @jax.jit
        def f(x, w):
            traces.append(1)
            return E.dense(x, w)

        x = jnp.ones((4, 16)); w = jnp.ones((16, 8))
        f(x, w); f(x, w); f(x + 1, w)
        assert len(traces) == 1                    # one trace, one compile

    def test_dense_einsum_macs_accounting(self):
        p = E.plan_einsum("...n,nm->...m", (7, 3, 64), (64, 32), "xla")
        assert p.macs == 7 * 3 * 64 * 32
        pe = E.plan_einsum("ecd,edf->ecf", (4, 9, 16), (4, 16, 8), "xla")
        assert pe.macs == 4 * 9 * 16 * 8

    def test_unsupported_specs_raise(self):
        with pytest.raises(ValueError):
            E.plan_einsum("ab,bc", (2, 3), (3, 4), "xla")     # no output
        with pytest.raises(ValueError):
            E.plan_einsum("ab,cd->ad", (2, 3), (3, 4), "xla")  # summed label


# ---------------------------------------------------------------------------
# Ledger / tracking
# ---------------------------------------------------------------------------

class TestLedger:
    def test_totals_identical_across_retraces(self):
        def f(x, w):
            return E.dense(E.conv2d(x, w, pad=1).reshape(x.shape[0], -1),
                           jnp.ones((12 * 12 * 16, 8), jnp.float32))

        x = jax.random.normal(jax.random.PRNGKey(0), (2, 12, 12, 8))
        w = jax.random.normal(jax.random.PRNGKey(1), (3, 3, 8, 16))
        with E.tracking() as eager:
            f(x, w)
        with E.tracking() as trace1:
            jax.jit(f)(x, w)
        jax.clear_caches()                         # force a genuine re-trace
        with E.tracking() as trace2:
            jax.jit(f)(x, w)
        assert (eager.total_cycles, eager.total_macs) \
            == (trace1.total_cycles, trace1.total_macs) \
            == (trace2.total_cycles, trace2.total_macs)
        assert len(eager) == len(trace1) == len(trace2) == 2

    def test_no_tracking_records_nothing(self):
        with E.tracking() as led:
            pass
        E.dense(jnp.ones((2, 4)), jnp.ones((4, 3)))
        assert len(led) == 0

    def test_nested_tracking_stacks(self):
        x, w = jnp.ones((2, 4)), jnp.ones((4, 3))
        with E.tracking() as outer:
            E.dense(x, w)
            with E.tracking() as inner:
                E.dense(x, w)
        assert len(outer) == 2 and len(inner) == 1

    def test_report_format(self):
        with E.tracking() as led:
            E.conv2d(jnp.ones((1, 8, 8, 4)), jnp.ones((3, 3, 4, 8)), pad=1)
        lines = led.report().splitlines()
        assert lines[0].startswith("kind,mode(Wf,S)")
        assert lines[1].startswith("conv2d,(3,1),3,")


# ---------------------------------------------------------------------------
# Backend registry
# ---------------------------------------------------------------------------

class TestRegistry:
    def test_builtin_backends_registered(self):
        assert set(BACKENDS) <= set(E.backend_names())

    def test_unknown_backend_raises(self):
        with pytest.raises(KeyError, match="unknown engine backend"):
            E.dense(jnp.ones((2, 4)), jnp.ones((4, 3)), backend="nope")

    def test_register_custom_backend(self):
        ref = E.get_backend("ref")
        custom = E.EngineBackend("_test_double", ref.conv2d,
                                 ref.conv1d_depthwise,
                                 lambda spec, x, w, plan, st, **kw:
                                 2.0 * jnp.einsum(spec, x, w))
        E.register_backend(custom, overwrite=True)
        x, w = jnp.ones((2, 4)), jnp.ones((4, 3))
        np.testing.assert_allclose(E.dense(x, w, backend="_test_double"),
                                   2.0 * (x @ w))
        with pytest.raises(ValueError, match="already registered"):
            E.register_backend(custom)

    def test_using_backend_ambient(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (1, 9, 9, 4))
        w = jax.random.normal(jax.random.PRNGKey(1), (3, 3, 4, 8))
        with E.tracking() as led, E.using_backend("ref"):
            E.conv2d(x, w, pad=1)
        assert led.records[0].plan.backend == "ref"


# ---------------------------------------------------------------------------
# EngineConfig: frozen, hashable, jit-static; context semantics
# ---------------------------------------------------------------------------

class TestEngineConfig:
    def test_frozen_hashable_equal(self):
        a = E.EngineConfig(backend="pallas", interpret=False)
        b = E.EngineConfig(backend="pallas", interpret=False)
        assert a == b and hash(a) == hash(b)
        assert {a: "v"}[b] == "v"
        with pytest.raises(Exception):
            a.backend = "xla"                       # frozen

    def test_invalid_policy_rejected(self):
        with pytest.raises(ValueError, match="policy"):
            E.EngineConfig(policy="greedy")

    def test_using_config_ambient(self):
        assert E.current_config().backend == "xla"
        with E.using_config(E.EngineConfig(backend="ref", interpret=False)):
            assert E.current_config().backend == "ref"
            assert not E.current_config().interpret
            with E.using_backend("pallas"):        # shim keeps other knobs
                assert E.current_config().backend == "pallas"
                assert not E.current_config().interpret
        assert E.current_config().backend == "xla"

    def test_set_default_backend_errors_in_context(self):
        # the old list stack silently ignored the write; now it's explicit
        with E.using_backend("ref"):
            with pytest.raises(RuntimeError, match="silently shadowed"):
                E.set_default_backend("xla")
            with pytest.raises(RuntimeError, match="silently shadowed"):
                E.set_interpret(False)
            with pytest.raises(RuntimeError, match="silently shadowed"):
                E.set_default_config(E.EngineConfig())
        # outside a context it is a well-defined base write
        E.set_default_backend("ref")
        try:
            assert E.default_backend() == "ref"
        finally:
            E.set_default_backend("xla")

    def test_config_as_static_jit_arg(self):
        from functools import partial
        traces = []

        @partial(jax.jit, static_argnums=0)
        def f(cfg, x, w):
            traces.append(1)
            with E.using_config(cfg):
                return E.dense(x, w)

        x, w = jnp.ones((4, 16)), jnp.ones((16, 8))
        f(E.EngineConfig(backend="ref"), x, w)
        f(E.EngineConfig(backend="ref"), x, w)      # equal config: cache hit
        assert len(traces) == 1
        f(E.EngineConfig(backend="xla"), x, w)      # distinct config: retrace
        assert len(traces) == 2

    def test_plan_cache_hits_across_retraces_under_config(self):
        from functools import partial

        @partial(jax.jit, static_argnums=0)
        def f(cfg, x, w):
            with E.using_config(cfg):
                return E.dense(x, w)

        x = jnp.ones((6, 24))
        w = jnp.ones((24, 12))
        cfg = E.EngineConfig(backend="xla")
        f(cfg, x, w)
        hits0 = E.plan_einsum.cache_info().hits
        jax.clear_caches()                          # force a genuine retrace
        f(cfg, x, w)
        assert E.plan_einsum.cache_info().hits > hits0

    def test_config_accum_policy(self):
        x = jnp.ones((2, 8), jnp.bfloat16)
        w = jnp.ones((8, 4), jnp.bfloat16)
        with E.using_config(E.EngineConfig(accum="float32")):
            y32 = E.einsum("...n,nm->...m", x, w)
        with E.using_config(E.EngineConfig(accum="native")):
            ynat = E.einsum("...n,nm->...m", x, w)
        assert y32.dtype == jnp.float32             # preferred_element_type
        assert ynat.dtype == jnp.bfloat16           # plain-@ numerics


# ---------------------------------------------------------------------------
# parse_einsum / plan_einsum edge cases
# ---------------------------------------------------------------------------

class TestEinsumEdgeCases:
    def test_ellipsis_on_both_operands(self):
        spec = "...ab,...bc->...ac"
        x = jax.random.normal(jax.random.PRNGKey(0), (4, 2, 3), jnp.float32)
        w = jax.random.normal(jax.random.PRNGKey(1), (4, 3, 5), jnp.float32)
        np.testing.assert_allclose(E.einsum(spec, x, w),
                                   jnp.einsum(spec, x, w), rtol=1e-6)
        p = E.plan_einsum(spec, (4, 2, 3), (4, 3, 5), "xla")
        assert p.macs == 4 * 2 * 3 * 5
        assert "batched weights" in p.note

    def test_repeated_labels_rejected(self):
        with pytest.raises(ValueError, match="repeated label"):
            E.plan_einsum("aa,ab->ab", (3, 3), (3, 4), "xla")
        with pytest.raises(ValueError, match="repeated label"):
            E.plan_einsum("ab,bb->ab", (2, 3), (3, 3), "xla")

    def test_zero_size_contract_dim(self):
        x = jnp.zeros((2, 0), jnp.float32)
        w = jnp.zeros((0, 3), jnp.float32)
        y = E.einsum("ab,bc->ac", x, w)
        np.testing.assert_array_equal(y, jnp.zeros((2, 3)))
        p = E.plan_einsum("ab,bc->ac", (2, 0), (0, 3), "xla")
        assert p.macs == 0 and p.cycles == 0
        assert p.performance_efficiency == 0.0      # no div-by-zero

    def test_zero_size_free_dim(self):
        p = E.plan_einsum("ab,bc->ac", (0, 4), (4, 3), "xla")
        assert p.macs == 0 and p.cycles == 0

    def test_outer_product_books_one_mac_per_output(self):
        # no contract labels: still a planable FC op, not zero work
        p = E.plan_einsum("a,b->ab", (3,), (5,), "xla")
        assert p.macs == 3 * 5


# ---------------------------------------------------------------------------
# Legacy shim equivalence (acceptance: identical AlexNet ledger totals)
# ---------------------------------------------------------------------------

class TestLegacyShim:
    def test_multi_mode_engine_importable_and_deprecated(self):
        with pytest.warns(DeprecationWarning):
            eng = MultiModeEngine(EngineConfig())
        y = eng.conv2d(jnp.ones((1, 8, 8, 4)), jnp.ones((3, 3, 4, 8)), pad=1)
        assert y.shape == (1, 8, 8, 8) and eng.total_cycles > 0

    def test_alexnet_ledger_matches_legacy_engine(self):
        key = jax.random.PRNGKey(0)
        params = cnn.init_cnn("alexnet", key)
        x = jax.random.normal(key, (1, 227, 227, 3), jnp.float32) * 0.1
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            old = MultiModeEngine(EngineConfig(backend="xla",
                                               track_analytics=True))
        y_old = cnn.apply_cnn("alexnet", params, x, old)
        with E.tracking() as led:
            y_new = cnn.apply_cnn("alexnet", params, x, backend="xla")
        np.testing.assert_allclose(y_old, y_new, rtol=1e-5, atol=1e-5)
        assert old.total_cycles == led.total_cycles
        assert old.total_macs == led.total_macs
        assert old.report() == led.report()
